"""Benchmark entry point — one function per paper table/figure plus the
framework-level analyses.  Prints ``name,us_per_call,derived`` CSV rows;
``--json PATH`` additionally writes the same rows (plus the git sha) as
a JSON list — the ``BENCH_planner.json`` schema:
``[{"name", "us_per_call", "derived", "git_sha"}, ...]``.

``--scenarios`` swaps in the lifecycle-scenario suite (all registered
scenarios, every default balancer from the planner registry).  The two
output flags compose: one invocation writes *both* artifacts — the CSV
rows of every suite that ran go to ``--json PATH``, and the full
per-tick scenario results go to ``--scenarios-out`` (default
``BENCH_scenarios.json``); the two paths are guarded against clobbering
each other.

``--trace-out PATH`` additionally records the whole run through the
telemetry spine (:mod:`repro.obs`): every planner call, batch chunk and
scenario tick becomes a span, and the registry counters land in the
trace footer — ``*.jsonl`` gets the native line format, any other suffix
a Chrome/Perfetto trace JSON.  Like the other artifacts it is guarded
against clobbering ``--json`` / ``--scenarios-out``.

    PYTHONPATH=src python -m benchmarks.run [--quick] [--json PATH]
        [--scenarios] [--scenarios-out PATH] [--seed N] [--trace-out PATH]
"""

from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import traceback


def git_sha() -> str:
    try:
        return subprocess.run(["git", "rev-parse", "--short", "HEAD"],
                              capture_output=True, text=True, check=True,
                              timeout=10).stdout.strip()
    except Exception:
        return "unknown"


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="small clusters only (A, C, F)")
    ap.add_argument("--json", metavar="PATH", default=None,
                    help="also write rows as JSON (BENCH_planner.json "
                         "schema: name, us_per_call, derived, git_sha)")
    ap.add_argument("--scenarios", action="store_true",
                    help="run the lifecycle-scenario suite instead of the "
                         "paper suites; composes with --json (rows) and "
                         "--scenarios-out (full per-tick results)")
    ap.add_argument("--scenarios-out", metavar="PATH",
                    default="BENCH_scenarios.json",
                    help="where the scenario suite writes its full results")
    ap.add_argument("--seed", type=int, default=0,
                    help="scenario-suite seed (ignored without --scenarios)")
    ap.add_argument("--trace-out", metavar="PATH", default=None,
                    help="also write a structured trace of the whole run "
                         "(repro.obs): *.jsonl gets the native line format, "
                         "any other suffix a Chrome/Perfetto trace JSON")
    args = ap.parse_args()

    if args.json and args.scenarios and \
            os.path.abspath(args.json) == os.path.abspath(args.scenarios_out):
        ap.error("--json and --scenarios-out point at the same file; the "
                 "rows artifact would clobber the scenario results")
    if args.trace_out:
        clashes = [args.json] + ([args.scenarios_out] if args.scenarios
                                 else [])
        if any(p and os.path.abspath(args.trace_out) == os.path.abspath(p)
               for p in clashes):
            ap.error("--trace-out points at another output artifact; the "
                     "trace would clobber it")

    if args.scenarios:
        from benchmarks.bench_scenarios import bench_scenarios

        def scenario_suite():
            _, rows = bench_scenarios(quick=args.quick, seed=args.seed,
                                      out=args.scenarios_out)
            return rows

        suites = [("scenarios", scenario_suite)]
    else:
        from benchmarks.paper_tables import (bench_planner_speed,
                                             bench_table1, bench_timing,
                                             bench_trajectories)
        from benchmarks.roofline import bench_roofline

        table1_clusters = ("A", "C", "F") if args.quick else ("A", "B", "C",
                                                              "D", "E", "F")
        traj_clusters = ("A",) if args.quick else ("A", "B")

        suites = [
            ("table1", lambda: bench_table1(table1_clusters)),
            ("trajectories", lambda: bench_trajectories(traj_clusters)),
            ("timing", lambda: bench_timing(traj_clusters)),
            ("planner_speed", bench_planner_speed),
            ("roofline", bench_roofline),
        ]

    tracer = None
    if args.trace_out:
        from repro import obs
        tracer = obs.start_tracing(args.trace_out)

    sha = git_sha()
    json_rows = []
    print("name,us_per_call,derived")
    failures = 0
    for name, fn in suites:
        try:
            for row_name, us, derived in fn():
                print(f"{row_name},{us:.1f},{derived}")
                json_rows.append({"name": row_name, "us_per_call": us,
                                  "derived": derived, "git_sha": sha})
        except Exception as e:
            failures += 1
            traceback.print_exc()
            print(f"{name},-1,FAILED:{e}")
            json_rows.append({"name": name, "us_per_call": -1,
                              "derived": f"FAILED:{e}", "git_sha": sha})
    if tracer is not None:
        from repro import obs
        obs.stop_tracing()
        print(f"# wrote trace -> {args.trace_out}", file=sys.stderr)
    if args.json:
        with open(args.json, "w") as f:
            json.dump(json_rows, f, indent=1)
        print(f"# wrote {len(json_rows)} rows -> {args.json}", file=sys.stderr)
    sys.exit(1 if failures else 0)


if __name__ == "__main__":
    main()
