"""Benchmark entry point — one function per paper table/figure plus the
framework-level analyses.  Prints ``name,us_per_call,derived`` CSV rows.

    PYTHONPATH=src python -m benchmarks.run [--quick]
"""

from __future__ import annotations

import argparse
import sys
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="small clusters only (A, C, F)")
    args = ap.parse_args()

    from benchmarks.paper_tables import (bench_planner_speed, bench_table1,
                                         bench_timing, bench_trajectories)
    from benchmarks.roofline import bench_roofline

    table1_clusters = ("A", "C", "F") if args.quick else ("A", "B", "C",
                                                          "D", "E", "F")
    traj_clusters = ("A",) if args.quick else ("A", "B")

    suites = [
        ("table1", lambda: bench_table1(table1_clusters)),
        ("trajectories", lambda: bench_trajectories(traj_clusters)),
        ("timing", lambda: bench_timing(traj_clusters)),
        ("planner_speed", bench_planner_speed),
        ("roofline", bench_roofline),
    ]

    print("name,us_per_call,derived")
    failures = 0
    for name, fn in suites:
        try:
            for row_name, us, derived in fn():
                print(f"{row_name},{us:.1f},{derived}")
        except Exception as e:
            failures += 1
            traceback.print_exc()
            print(f"{name},-1,FAILED:{e}")
    sys.exit(1 if failures else 0)


if __name__ == "__main__":
    main()
