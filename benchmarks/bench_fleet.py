"""Fleet planning throughput: one vmapped dispatch vs N serial planners.

Three benchmark families, all over lanes the serial engine plans
bit-identically (checked per run, reported per row):

* ``fleet.stream``  — the headline: N independent clusters driven to
  convergence in fine-grained streaming mode (``chunk=1``, the SLO
  granularity a latency-bounded service plans at).  Here per-dispatch
  fixed cost (jit call + host sync + Python) dominates compute, and the
  fleet pays it once per bucket-round instead of once per cluster-move:
  the measured speedup *is* the dispatch amortization, and the move
  streams must match the serial planners move-for-move.
* ``fleet.loadgen`` — N concurrent scenario lifecycles
  (:class:`repro.fleet.loadgen.FleetLoadGen`) on one planner:
  steady-growth emits only absorbable deltas, so each cluster's whole
  lifecycle must cost exactly one dense rebuild (the initial pack) —
  the row carries ``max_rebuilds`` for CI to assert on.
* ``fleet.slo``     — a deliberately impossible deadline: the tick must
  still return a *valid partial* plan (every returned move replays
  legally on a twin) with ``slo_expired`` set.

Rows follow the repo bench schema ``{name, us_per_call, derived,
git_sha}`` (BENCH_fleet.json); every timed call runs inside a
``bench.call`` span with counter deltas attached, so
``tools/tracestat.py --bench`` / ``--fleet`` reproduce the derived
columns from the trace alone.  Host-sync accounting comes from the
``batch.host_syncs`` registry counter: the fleet's syncs-per-step must
stay at the *single-cluster* bound (one sync per bucket-round, however
many lanes), which CI asserts via the emitted fields.

    PYTHONPATH=src python -m benchmarks.bench_fleet [--quick] [--out P]
        [--trace-out P]
"""

from __future__ import annotations

import argparse
import json
import time

import numpy as np

from benchmarks.run import git_sha
from repro import obs
from repro.core import (Device, PlacementRule, Pool, TiB, build_cluster,
                        create_planner)
from repro.fleet import FleetLoadGen, FleetPlanner, FleetService
from repro.obs.metrics import registry

#: streaming-mode geometry: chunk=1 is the finest SLO granularity (one
#: move per dispatch per lane) — the regime the fleet exists for
CHUNK, ROW_BLOCK, ROW_CAPACITY = 1, 8, 128


def _mk_cluster(i: int):
    """Heterogeneous-but-bucketable genome: 12..15 OSDs (pads to one
    16-wide bucket), per-cluster pg counts, mixed 2/4/16 TiB devices."""
    rng = np.random.default_rng(100 + i)
    n_dev = 12 + (i % 4)
    devs, h = [], 0
    while len(devs) < n_dev:
        for _ in range(3):
            if len(devs) >= n_dev:
                break
            cap = float(rng.choice([2, 4, 16])) * TiB
            devs.append(Device(id=len(devs), capacity=cap,
                               device_class="hdd", host=f"host{h}"))
        h += 1
    total = sum(d.capacity for d in devs)
    pools = [Pool(0, "p0", 21 + i, PlacementRule.replicated(3, "host"),
                  stored_bytes=0.45 * total / 3),
             Pool(1, "p1", 13 + i, PlacementRule.replicated(2, "host"),
                  stored_bytes=0.30 * total / 2)]
    return build_cluster(devs, pools, seed=i)


def _mk_fleet(n: int) -> FleetPlanner:
    fp = FleetPlanner(chunk=CHUNK, row_block=ROW_BLOCK)
    for i in range(n):
        # pinning the carry row axis lands every cluster in one bucket:
        # one compiled program, one host sync per fleet round
        fp.add_cluster(i, _mk_cluster(i), row_capacity=ROW_CAPACITY)
    return fp


def _mk_serial(n: int) -> dict:
    out = {}
    for i in range(n):
        p = create_planner("equilibrium_batch", chunk=CHUNK,
                           row_block=ROW_BLOCK, select_backend="ref",
                           legality_cache=False, source_bounds=True)
        out[i] = (p, _mk_cluster(i))
    return out


def _drive_fleet(fp: FleetPlanner, n: int, budget: int):
    """Fleet ticks until a tick emits no moves; returns per-lane move
    keys and the tick count."""
    moves = {i: [] for i in range(n)}
    ticks = 0
    while True:
        ticks += 1
        got = 0
        for k, res in fp.plan_fleet({i: budget for i in range(n)}).items():
            moves[k].extend((m.pg, m.slot, m.src_osd, m.dst_osd)
                            for m in res.moves)
            got += len(res.moves)
        if got == 0:
            return moves, ticks


def _drive_serial(planners: dict, budget: int):
    moves, calls = {}, 0
    for k, (p, s) in planners.items():
        acc = []
        while True:
            calls += 1
            got = p.plan(s, budget=budget).moves
            acc.extend((m.pg, m.slot, m.src_osd, m.dst_osd) for m in got)
            if not got:
                break
        moves[k] = acc
    return moves, calls


def bench_stream(n: int, budget: int, repeats: int = 5) -> list[dict]:
    """Headline: N clusters to convergence, fleet vs serial loop.
    Best-of-``repeats`` on fresh twins each round — convergence consumes
    the state, so a repeat is a rebuild, not a re-run, and single-run
    jitter on a shared CPU is the dominant noise source."""
    sha = git_sha()
    reg = registry()

    # jit warmup on scratch twins (compile excluded, as in bench_planner)
    _drive_fleet(_mk_fleet(n), n, budget)
    _drive_serial(_mk_serial(n), budget)

    fleet_s = serial_s = float("inf")
    identical = True
    for _ in range(repeats):
        # fresh twins, both pre-packed by a budget=1 tick so the timed
        # window is pure steady-state streaming (no pack/rebuild inside)
        fp = _mk_fleet(n)
        fleet_moves = {k: [(m.pg, m.slot, m.src_osd, m.dst_osd)
                           for m in res.moves]
                       for k, res in fp.plan_fleet({i: 1 for i in range(n)}
                                                   ).items()}
        planners = _mk_serial(n)
        serial_moves = {k: [(m.pg, m.slot, m.src_osd, m.dst_osd)
                            for m in p.plan(s, budget=1).moves]
                        for k, (p, s) in planners.items()}

        snap = reg.snapshot()
        with obs.span("bench.call", cat="bench", counters=True,
                      name="fleet.stream.fleet") as sp:
            t0 = time.perf_counter()
            fm, ticks = _drive_fleet(fp, n, budget)
            dt_f = time.perf_counter() - t0
            sp.set(moves=sum(len(v) for v in fm.values()))
        fleet_syncs = int(reg.deltas_since(snap).get("batch.host_syncs", 0))

        snap = reg.snapshot()
        with obs.span("bench.call", cat="bench", counters=True,
                      name="fleet.stream.serial") as sp:
            t0 = time.perf_counter()
            sm, calls = _drive_serial(planners, budget)
            dt_s = time.perf_counter() - t0
            sp.set(moves=sum(len(v) for v in sm.values()))
        serial_syncs = int(reg.deltas_since(snap).get("batch.host_syncs", 0))

        for k in range(n):
            fleet_moves[k] += fm[k]
            serial_moves[k] += sm[k]
        identical = identical and fleet_moves == serial_moves
        fleet_s = min(fleet_s, dt_f)
        serial_s = min(serial_s, dt_s)
    n_moves = sum(len(v) for v in fleet_moves.values())
    speedup = serial_s / max(fleet_s, 1e-9)
    # one sync per bucket-round: per fleet step the whole fleet costs
    # what one cluster's chunk dispatch costs
    fleet_per_step = fleet_syncs / max(ticks, 1)
    serial_per_cluster = serial_syncs / max(n, 1)
    print(f"  stream: {n} clusters, {n_moves} moves | fleet {fleet_s:.3f}s "
          f"({ticks} ticks, {fleet_syncs} syncs) vs serial {serial_s:.3f}s "
          f"({calls} calls, {serial_syncs} syncs) -> {speedup:.2f}x "
          f"identical={identical}")
    shared = (f"clusters={n};moves={n_moves};speedup={speedup:.2f}x;"
              f"identical={identical};fleet_s={fleet_s:.4f};"
              f"serial_s={serial_s:.4f}")
    return [
        {"name": "fleet.stream.fleet",
         "us_per_call": 1e6 * fleet_s / max(n_moves, 1),
         "derived": (f"{shared};ticks={ticks};host_syncs={fleet_syncs};"
                     f"syncs_per_step={fleet_per_step:.1f};"
                     f"single_cluster_sync_bound={serial_per_cluster:.1f}"),
         "git_sha": sha},
        {"name": "fleet.stream.serial",
         "us_per_call": 1e6 * serial_s / max(n_moves, 1),
         "derived": (f"{shared};plan_calls={calls};"
                     f"host_syncs={serial_syncs}"),
         "git_sha": sha},
    ]


def bench_loadgen(n: int) -> list[dict]:
    """Absorb-only lifecycles: steady-growth deltas stream into lanes
    and must absorb in place — exactly one rebuild per cluster (the
    initial pack), ever."""
    sha = git_sha()
    lg = FleetLoadGen(["steady-growth"] * n, seeds=list(range(n)),
                      quick=True)
    with obs.span("bench.call", cat="bench", counters=True,
                  name="fleet.loadgen.absorb") as sp:
        t0 = time.perf_counter()
        lg.run()
        wall = time.perf_counter() - t0
        summary = lg.summary()
        sp.set(moves=summary["total_moves"])
    max_rebuilds = max(acc["rebuilds"]
                       for acc in summary["per_cluster"].values())
    print(f"  loadgen: {n}x steady-growth, {summary['fleet_ticks']} fleet "
          f"ticks, {summary['total_moves']} moves, max_rebuilds="
          f"{max_rebuilds}, slo_hit_rate={summary['slo_hit_rate']:.2f}")
    return [{
        "name": "fleet.loadgen.absorb",
        "us_per_call": 1e6 * wall / max(summary["fleet_ticks"], 1),
        "derived": (f"clusters={n};ticks={summary['ticks']};"
                    f"fleet_ticks={summary['fleet_ticks']};"
                    f"moves={summary['total_moves']};"
                    f"max_rebuilds={max_rebuilds};"
                    f"slo_hit_rate={summary['slo_hit_rate']:.2f}"),
        "git_sha": sha,
    }]


def bench_slo(n: int, budget: int) -> list[dict]:
    """An impossible deadline must yield a valid partial plan: fewer
    moves than the unconstrained twin, every one legal on replay."""
    sha = git_sha()
    fp = _mk_fleet(n)
    fp.plan_fleet({i: 1 for i in range(n)})        # warm + pack
    service = FleetService(planner=fp, slo_seconds=0.0)
    with obs.span("bench.call", cat="bench", counters=True,
                  name="fleet.slo.partial") as sp:
        t0 = time.perf_counter()
        tick = service.tick({i: budget for i in range(n)})
        wall = time.perf_counter() - t0
        sp.set(moves=tick.total_moves)
    # validity: every returned move replays legally on a fresh twin that
    # saw the same pre-tick move
    legal = True
    for k, res in tick.results.items():
        twin = _mk_cluster(k)
        pre = create_planner("equilibrium_batch", chunk=CHUNK,
                             row_block=ROW_BLOCK, select_backend="ref",
                             legality_cache=False)
        pre.plan(twin, budget=1)                   # replays the pre-tick
        for m in res.moves:
            legal &= twin.move_is_legal(m.pg, m.slot, m.dst_osd)
            twin.apply(m)
    print(f"  slo: deadline=0s -> expired={tick.slo_expired}, "
          f"{tick.total_moves} partial moves, legal={legal}")
    return [{
        "name": "fleet.slo.partial",
        "us_per_call": 1e6 * wall,
        "derived": (f"clusters={n};slo_expired={tick.slo_expired};"
                    f"moves={tick.total_moves};legal={legal};"
                    f"budget={budget}"),
        "git_sha": sha,
    }]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="small fleet, short lifecycles")
    ap.add_argument("--out", default="BENCH_fleet.json")
    ap.add_argument("--trace-out", default=None,
                    help="keep the bench trace (*.jsonl native, otherwise "
                         "Chrome/Perfetto JSON); default: in-memory only")
    args = ap.parse_args()

    n = 8                       # the acceptance point: N=8 quick clusters
    n_loadgen = 2 if args.quick else 4
    budget = 64

    # tracer first: the telemetry flag is jit-static, so installing it
    # after warmup would recompile inside the timed window
    started = not obs.enabled()
    if started:
        obs.start_tracing(args.trace_out)
    rows = []
    rows += bench_stream(n, budget)
    rows += bench_loadgen(n_loadgen)
    rows += bench_slo(n, budget)
    if started:
        obs.stop_tracing()
        if args.trace_out:
            print(f"wrote trace -> {args.trace_out}")
    with open(args.out, "w") as f:
        json.dump(rows, f, indent=1)
    print(f"wrote {len(rows)} rows -> {args.out}")


if __name__ == "__main__":
    main()
