"""Run one lifecycle scenario and watch the cluster move.

    PYTHONPATH=src python examples/scenario_demo.py \
        --scenario cascading-failures --balancer equilibrium_batch

Prints a per-tick table (physical utilization variance, max device
utilization, transfer backlog, cumulative moved TiB) with event
annotations, then the summary — the interactive view of what
``python -m benchmarks.run --scenarios`` measures in bulk.
"""

import argparse

from repro import obs
from repro.core import TiB, available_planners
from repro.sim import SCENARIOS, run_scenario

ap = argparse.ArgumentParser()
ap.add_argument("--scenario", choices=sorted(SCENARIOS),
                default="steady-growth")
ap.add_argument("--balancer", choices=available_planners(),
                default="equilibrium_batch")
ap.add_argument("--seed", type=int, default=0)
ap.add_argument("--quick", action="store_true", help="short tick count")
ap.add_argument("--stride", type=int, default=1,
                help="print every Nth tick")
ap.add_argument("--trace-out", default=None, metavar="PATH",
                help="write the run's repro.obs trace (*.jsonl native, "
                     "otherwise Chrome/Perfetto JSON for tools/tracestat.py)")
args = ap.parse_args()

print(f"scenario {args.scenario!r} ({SCENARIOS[args.scenario].description})")
# the run is traced (in-memory unless --trace-out): every tick and plan
# call is a span, and the timing footer below is read back from it
with obs.tracing(args.trace_out) as trace:
    result = run_scenario(args.scenario, args.balancer, seed=args.seed,
                          quick=args.quick)
m = result["metrics"]
events_at = {}
for tick, desc in m["events"]:
    events_at.setdefault(tick, []).append(desc.split("(")[0])

print(f"{'tick':>5} {'variance':>10} {'max_util':>9} {'backlog':>8} "
      f"{'moved_TiB':>10}  events")
last = len(m["ticks"]) - 1
for i, t in enumerate(m["ticks"]):
    if i % args.stride and i != last:
        continue
    note = ",".join(events_at.get(t, []))
    print(f"{t:>5} {m['variance'][i]:>10.6f} {m['max_util'][i]:>9.3f} "
          f"{m['backlog_moves'][i]:>8} "
          f"{m['transferred_bytes'][i] / TiB:>10.2f}  {note}")

s = m["summary"]
print(f"\n{args.balancer}: final variance {s['final_variance']:.3e} "
      f"(target {s['final_variance_target']:.3e}), "
      f"moved {s['total_transferred_bytes'] / TiB:.2f} TiB in "
      f"{s['total_planned_moves']} planned moves, "
      f"{s['ticks_above_threshold']} ticks above fullness threshold, "
      f"{s['final_degraded']} degraded shards")

wall: dict[str, float] = {}
for r in trace.records:
    if r.get("ev") == "span":
        wall[r["name"]] = wall.get(r["name"], 0.0) + r["dur"] / 1e6
print(f"timing (repro.obs): scenario {wall.get('sim.scenario', 0.0):.2f}s, "
      f"planner {wall.get('planner.plan', 0.0):.2f}s"
      + (f", device chunks {wall['batch.chunk']:.2f}s"
         if "batch.chunk" in wall else ""))
if args.trace_out:
    print(f"wrote trace -> {args.trace_out} "
          f"(summarize: python tools/tracestat.py {args.trace_out})")
