"""Quickstart: the paper in ~40 lines.

Build a small heterogeneous cluster, run Ceph's count-based balancer and
Equilibrium on identical copies, and compare gained capacity, movement
volume, and utilization variance (Table-1-style row).

    PYTHONPATH=src python examples/quickstart.py
"""

from repro import obs
from repro.core import (EquilibriumConfig, MgrBalancerConfig, TiB,
                        create_planner, simulate, small_test_cluster)

initial = small_test_cluster()
print(f"cluster: {initial.n_devices} OSDs, {len(initial.acting)} PGs, "
      f"utilization {initial.utilization().min():.2f}"
      f"–{initial.utilization().max():.2f}, "
      f"variance {initial.utilization_variance():.4f}")

# every plan() call is a span on the telemetry spine; trace in-memory
# and read the timing back from the records instead of timing by hand
with obs.tracing() as trace:
    mgr_moves = create_planner("mgr", cfg=MgrBalancerConfig()) \
        .plan(initial.copy()).moves
    eq_moves = create_planner("equilibrium", cfg=EquilibriumConfig()) \
        .plan(initial.copy()).moves

for name, moves in (("ceph mgr balancer", mgr_moves),
                    ("equilibrium      ", eq_moves)):
    res = simulate(initial, moves, record_trajectory=False)
    print(f"{name}: {len(moves):3d} moves | "
          f"gained {res.gained_free_space / TiB:6.2f} TiB | "
          f"moved {res.moved_bytes / TiB:5.2f} TiB | "
          f"variance {res.variance_before:.4f} → {res.variance_after:.5f}")

print("\nplanner timing (from the repro.obs trace):")
for r in trace.records:
    if r.get("ev") == "span" and r["name"] == "planner.plan":
        a = r["args"]
        print(f"  {a['planner']:12s} {r['dur'] / 1e3:8.1f} ms wall "
              f"({a['moves']} moves)")
