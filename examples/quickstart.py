"""Quickstart: the paper in ~40 lines.

Build a small heterogeneous cluster, run Ceph's count-based balancer and
Equilibrium on identical copies, and compare gained capacity, movement
volume, and utilization variance (Table-1-style row).

    PYTHONPATH=src python examples/quickstart.py
"""

from repro.core import (EquilibriumConfig, MgrBalancerConfig, TiB,
                        create_planner, simulate, small_test_cluster)

initial = small_test_cluster()
print(f"cluster: {initial.n_devices} OSDs, {len(initial.acting)} PGs, "
      f"utilization {initial.utilization().min():.2f}"
      f"–{initial.utilization().max():.2f}, "
      f"variance {initial.utilization_variance():.4f}")

mgr_moves = create_planner("mgr", cfg=MgrBalancerConfig()) \
    .plan(initial.copy()).moves
eq_moves = create_planner("equilibrium", cfg=EquilibriumConfig()) \
    .plan(initial.copy()).moves

for name, moves in (("ceph mgr balancer", mgr_moves),
                    ("equilibrium      ", eq_moves)):
    res = simulate(initial, moves, record_trajectory=False)
    print(f"{name}: {len(moves):3d} moves | "
          f"gained {res.gained_free_space / TiB:6.2f} TiB | "
          f"moved {res.moved_bytes / TiB:5.2f} TiB | "
          f"variance {res.variance_before:.4f} → {res.variance_after:.5f}")
