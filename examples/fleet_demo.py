"""Plan a small fleet of clusters with one vmapped service.

    PYTHONPATH=src python examples/fleet_demo.py [--slo-ms 5] [--ticks 4]

Three heterogeneous clusters attach to one :class:`repro.fleet.
FleetService`; each balancing interval plans *all* of them in a single
vmapped dispatch per shape bucket.  Between ticks, pool growth streams
into one lane as deltas the warm carry absorbs in place (no dense
rebuild).  The per-tick table shows each cluster's partial/complete
plan under the latency SLO; the footer summarizes the trace the run
recorded (the same spans ``tools/tracestat.py --fleet`` tabulates).
"""

import argparse

import numpy as np

from repro import obs
from repro.core import Device, PlacementRule, Pool, TiB, build_cluster

GiB = TiB / 1024


def make_cluster(i: int):
    """12–14 OSDs over mixed 2/4/16 TiB devices, two replicated pools."""
    rng = np.random.default_rng(7 + i)
    devs = []
    for d in range(12 + i):
        cap = float(rng.choice([2, 4, 16])) * TiB
        devs.append(Device(id=d, capacity=cap, device_class="hdd",
                           host=f"host{d // 3}"))
    total = sum(d.capacity for d in devs)
    pools = [Pool(0, "rbd", 24 + i, PlacementRule.replicated(3, "host"),
                  stored_bytes=0.45 * total / 3),
             Pool(1, "rgw", 14 + i, PlacementRule.replicated(2, "host"),
                  stored_bytes=0.30 * total / 2)]
    return build_cluster(devs, pools, seed=i)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--ticks", type=int, default=4)
    ap.add_argument("--budget", type=int, default=16,
                    help="moves per cluster per tick")
    ap.add_argument("--slo-ms", type=float, default=None,
                    help="per-tick latency SLO (unset: no deadline)")
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="write the run's repro.obs trace for "
                         "tools/tracestat.py --fleet")
    args = ap.parse_args()

    from repro.fleet import FleetService    # after CLI: imports touch jax

    slo = None if args.slo_ms is None else args.slo_ms / 1e3
    service = FleetService(chunk=max(1, args.budget // 2), slo_seconds=slo)
    states = {}
    for i in range(3):
        key = f"cluster-{i}"
        states[key] = make_cluster(i)
        service.attach(key, states[key])
        u = states[key].utilization()
        print(f"{key}: {states[key].n_devices} OSDs, util "
              f"{u.min():.2f}..{u.max():.2f}, "
              f"variance {states[key].utilization_variance():.5f}")

    with obs.tracing(args.trace_out) as trace:
        for t in range(args.ticks):
            if t == 2:
                # out-of-band growth streams into one lane; the warm
                # carry absorbs it without a dense rebuild
                states["cluster-1"].grow_pool(0, 256 * GiB)
                print("tick 2: +256 GiB into cluster-1/rbd "
                      "(delta absorbed in place)")
            result = service.tick(
                {k: args.budget for k in states})
            for key in sorted(states):
                plan = result.results[key]
                s = plan.stats
                print(f"  t={t} {key}: {len(plan.moves):>3} moves  "
                      f"variance {s['variance_after']:.6f}  "
                      f"converged={s['converged']}"
                      + ("  SLO-cut" if s["slo_expired"] else ""))

    ticks = [r for r in trace.records
             if r["ev"] == "span" and r["name"] == "fleet.tick"]
    chunks = sum(r.get("args", {}).get("chunks", 0) for r in ticks)
    counters = next((r for r in reversed(trace.records)
                     if r["ev"] == "counters"), {"values": {}})["values"]
    print(f"\n{len(ticks)} fleet ticks, {chunks} vmapped dispatches, "
          f"{int(counters.get('batch.host_syncs', 0))} host syncs, "
          f"{int(counters.get('batch.rebuilds', 0))} dense rebuilds, "
          f"{int(counters.get('absorb.runs', 0))} absorb runs")
    for key in sorted(states):
        print(f"{key}: final variance "
              f"{states[key].utilization_variance():.6f}")
    if args.trace_out:
        print(f"trace -> {args.trace_out} "
              f"(tools/tracestat.py {args.trace_out} --fleet)")


if __name__ == "__main__":
    main()
