"""End-to-end serving driver: batched requests through the decode engine
with Equilibrium-balanced paged KV — the paper's capacity story live:
admission is min-gated by the fullest chip; rebalancing restores headroom.

    PYTHONPATH=src python examples/serve_paged.py --requests 12
"""

import argparse

import jax
import numpy as np

from repro.configs import get_config
from repro.models import init_params
from repro.serve import PagedKVPool, PagedKVSpec, Request, ServeEngine

ap = argparse.ArgumentParser()
ap.add_argument("--arch", default="qwen3-0.6b")
ap.add_argument("--requests", type=int, default=8)
ap.add_argument("--new-tokens", type=int, default=16)
args = ap.parse_args()

cfg = get_config(args.arch).reduced(n_layers=2, vocab_size=256)
params = init_params(cfg, jax.random.PRNGKey(0))
pool = PagedKVPool(PagedKVSpec(n_chips=4, page_tokens=16, pages_per_chip=128))
engine = ServeEngine(cfg, params, batch_slots=4, max_len=128, pool=pool)

rng = np.random.default_rng(0)
for i in range(args.requests):
    prompt = rng.integers(1, cfg.vocab_size, size=int(rng.integers(4, 12)))
    engine.submit(Request(id=i, prompt=prompt,
                          max_new_tokens=args.new_tokens))

steps = 0
while engine.queue or engine.active:
    info = engine.step()
    steps += 1
    if info.get("finished"):
        print(f"step {steps:4d}: finished {info['finished']} "
              f"(active {info['active']}, queued {info['queued']}, "
              f"pool util {pool.utilization().round(2)})")
    if steps > 5000:
        raise SystemExit("did not converge")

print(f"served {args.requests} requests in {steps} decode steps; "
      f"KV migrated by Equilibrium: {engine.migrated_bytes / 1e6:.1f} MB")
