"""End-to-end training driver example (reduced arch on CPU):

    PYTHONPATH=src python examples/train_loop.py

Full driver with checkpoints/restore: python -m repro.launch.train --help
"""

import subprocess
import sys

subprocess.run([sys.executable, "-m", "repro.launch.train",
                "--arch", "qwen3-0.6b", "--steps", "30",
                "--batch", "8", "--seq", "128"], check=True,
               env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin"})
