"""Reproduce the paper's per-cluster experiment on any of clusters A–F:
both balancers from the same initial state, Table-1 row + trajectory CSV.

    PYTHONPATH=src python examples/balance_cluster.py --cluster A
"""

import argparse
import csv

from repro.core import (EquilibriumConfig, MgrBalancerConfig, PAPER_CLUSTERS,
                        TiB, create_planner, simulate)

ap = argparse.ArgumentParser()
ap.add_argument("--cluster", choices=sorted(PAPER_CLUSTERS), default="A")
ap.add_argument("--max-moves", type=int, default=10_000)
ap.add_argument("--engine", default="equilibrium",
                choices=("equilibrium", "equilibrium_batch",
                         "equilibrium_batch_sharded",
                         "equilibrium_jax_legacy"),
                help="Equilibrium planner: dense-NumPy (default), the "
                     "device-resident batched engine, its shard_map-ped "
                     "mesh variant, or the per-source legacy JAX path — "
                     "all bit-identical")
ap.add_argument("--trajectory-csv", default=None)
args = ap.parse_args()

initial = PAPER_CLUSTERS[args.cluster]()
print(f"cluster {args.cluster}: {initial.n_devices} OSDs, "
      f"{len(initial.acting)} PGs, {len(initial.pools)} pools")

results = {}
for name, planner_name, cfg in (
        ("default", "mgr", MgrBalancerConfig(max_moves=args.max_moves)),
        ("equilibrium", args.engine,
         EquilibriumConfig(max_moves=args.max_moves))):
    moves = create_planner(planner_name, cfg=cfg).plan(initial.copy()).moves
    res = simulate(initial, moves, trajectory_stride=max(1, len(moves) // 100))
    results[name] = res
    print(f"  {name:12s}: {len(moves):5d} moves | gained "
          f"{res.gained_free_space / TiB:8.2f} TiB | moved "
          f"{res.moved_bytes / TiB:7.2f} TiB | var "
          f"{res.variance_after:.6f} | per-class "
          f"{ {k: round(v, 6) for k, v in res.variance_by_class_after.items()} }")

if args.trajectory_csv:
    with open(args.trajectory_csv, "w", newline="") as f:
        w = csv.writer(f)
        w.writerow(["balancer", "sample", "variance", "free_TiB", "moved_TiB"])
        for name, res in results.items():
            for i, (v, fr, mv) in enumerate(zip(res.variance_trajectory,
                                                res.free_trajectory,
                                                res.moved_bytes_trajectory)):
                w.writerow([name, i, v, fr / TiB, mv / TiB])
    print(f"trajectories → {args.trajectory_csv}")
