"""Equilibrium as MoE infrastructure: place 32 experts × 2 replicas on a
16-chip EP group, skew the token load, and watch the balancer migrate the
hot experts' replicas with explicit byte-cost accounting.

    PYTHONPATH=src python examples/expert_placement_demo.py
"""

import numpy as np

from repro.sharding.expert_placement import (ExpertClusterSpec, apply_loads,
                                             migration_bytes, plan, rebalance)

L, E = 4, 32
expert_bytes = 512e6                       # ~mixtral-size expert slice
spec = ExpertClusterSpec(n_chips=16, chips_per_host=4,
                         hbm_budget_bytes=12e9, replicas=2)
placement = plan(L, E, expert_bytes, spec)
print("initial chip utilization:", placement.chip_utilization().round(3))

# skew: experts 0–3 of every layer get 8× the average token load
loads = np.ones((L, E))
loads[:, :4] = 8.0
apply_loads(placement, loads, expert_bytes)
print("after load skew:        ", placement.chip_utilization().round(3),
      "var=%.5f" % placement.state.utilization_variance())

moves = rebalance(placement)
print(f"equilibrium: {len(moves)} expert migrations, "
      f"{migration_bytes(moves) / 1e9:.2f} GB over ICI")
print("after rebalance:        ", placement.chip_utilization().round(3),
      "var=%.5f" % placement.state.utilization_variance())
